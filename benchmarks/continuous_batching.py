"""Continuous vs static batching under Poisson arrivals (DESIGN.md §11;
the serving analog of the paper's §5.1 sustained multi-utterance E2E
evaluation).

Static run-to-completion batches lose utilization two ways the paper's
always-busy accelerator forbids: early-finished rows burn jitted steps
until the batch drains, and new arrivals head-of-line block behind it.
This benchmark replays the SAME staggered Poisson arrival trace through
both serving modes on whisper-tiny (dense bf16 and Q8_0+offload) and
reports aggregate tok/s, p50/p95 request latency, and PDP.

Method: a virtual-clock discrete-event replay driven by *calibrated*
service times — batch prefill, batch decode step, scheduler admission
(batch-1 prefill + slot splice + bookkeeping) and scheduler step (incl.
its host sync) are each estimated as the MINIMUM over interleaved
repeated probes (timing noise on a shared machine is strictly additive,
so the min is the robust estimate of an op's true cost), then the
arrival trace is replayed through both modes advancing the clock by
those constants. Every prefill/step still executes for real (token
streams, ledger commits, retrace counting are all live); only the clock
uses the calibrated constants, so a single noisy call on a shared CI
machine cannot flip the comparison. No sleeping — the run is fast and
deterministic given the probes.

Invariants asserted every run (exit code gates CI via ``--smoke``):
  - continuous >= static on aggregate tok/s AND <= on p95 latency
  - zero decode step_fn retraces after warmup (fixed-shape slot pool)
  - per-request ledger PDP attribution sums to the batch total
  - telemetry (DESIGN.md §16) invariants on a dedicated q8_0+offload
    drain: every lifecycle span closes, span nesting holds, and the sum
    of ledger-span FLOP deltas equals the ledger total EXACTLY (§16.2).
    The drain is OUTSIDE the gated measurement — span recording is host
    work per step, and the vs-static gate calibrates per-step cost, so
    attaching telemetry there would fold its overhead into the gated
    constants (the overhead budget itself is gated by
    ``benchmarks.telemetry_overhead``)

Latency percentiles (p50/p95/p99) come from the shared ``obs.metrics``
histogram in exact (track_values) mode — one percentile implementation
across the serving benchmarks, with the CI gates still comparing exact
values, never bucket edges.

Usage:
  PYTHONPATH=src python -m benchmarks.continuous_batching [--smoke]
      [--trace-out PATH] [--metrics-out PATH]

Writes experiments/bench/continuous_batching.json.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import jax
import numpy as np

from benchmarks.common import fmt_table, save
from repro import obs
from repro.configs.registry import get_config, get_smoke_config
from repro.core import energy
from repro.core.offload import OffloadEngine
from repro.models import model as model_lib
from repro.obs.metrics import LATENCY_BUCKETS_S, Histogram
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import ContinuousBatchingScheduler


def _latency_summary(xs: List[float]) -> Dict[str, float]:
    """p50/p95/p99 through the ONE shared percentile implementation
    (repro.obs.metrics, DESIGN.md §16.3), in exact mode: the continuous-
    vs-static p95 gate compares real values, so the summary must not
    quantize to bucket edges."""
    h = Histogram("latency_s", LATENCY_BUCKETS_S, track_values=True)
    for x in xs:
        h.observe(x)
    return {"p50_s": h.percentile(50), "p95_s": h.percentile(95),
            "p99_s": h.percentile(99)}


def _calibrate(engine: ServeEngine, mel0: np.ndarray, n_slots: int,
               n_frames: int, rounds: int = 5) -> Dict[str, float]:
    """Min-over-probes service times for the virtual clock. Warmup
    (compilation of the batch-B static path, the batch-1 admission
    prefill, and the shared decode step) happens first; then the
    static-path and scheduler-path probes run INTERLEAVED round-robin so
    a noisy patch on a shared machine lands on both modes' samples alike
    — the gated comparison depends on the ratio of the two modes'
    per-step costs (the same compiled step plus each mode's own host
    overhead), and min-over-interleaved-rounds keeps that ratio stable."""
    warm = np.concatenate([mel0] * n_slots, axis=0)
    engine.transcribe(warm, max_new=6)                       # compile
    sched = ContinuousBatchingScheduler(engine, n_slots=n_slots,
                                        n_frames=n_frames)
    sched.submit(mel0, max_new=2)
    sched.run()                                              # compile admit
    pf_b, st_b, admits, csteps = [], [], [], []
    for _ in range(rounds):
        r = engine.transcribe(warm, max_new=6)
        pf_b.append(r[0].prefill_s * n_slots)
        st_b.append(r[0].decode_s * n_slots / max(r[0].steps, 1))
        for _ in range(2):
            sched.submit(mel0, max_new=4)
        while sched.n_queued or sched.n_active:
            if sched.n_queued and sched.pool.n_free:
                t0 = time.perf_counter()
                n = len(sched.admit())
                admits.append((time.perf_counter() - t0) / max(n, 1))
            t0 = time.perf_counter()
            sched.decode_step()
            csteps.append(time.perf_counter() - t0)
    # min, not median: timing noise on a shared machine is strictly
    # additive, so the minimum is the robust estimate of each op's true
    # cost — and since the replay is deterministic given these constants,
    # it is the only run-to-run variance source for the gated comparison
    return {"t_prefill_b": float(np.min(pf_b)),
            "t_step_b": float(np.min(st_b)),
            "t_admit": float(np.min(admits)),
            "t_cstep": float(np.min(csteps))}


def _run_static(engine: ServeEngine, mels: List[np.ndarray],
                max_news: List[int], arrivals: np.ndarray, n_slots: int,
                cal: Dict[str, float]) -> Dict[str, float]:
    """Static run-to-completion batching on the arrival trace: when the
    engine frees up it takes the up-to-``n_slots`` oldest *arrived*
    requests (padding the batch to the fixed width by repeating the last
    utterance — shapes stay static) and decodes the whole batch to the
    max of its members' budgets; members all complete at batch drain."""
    t, done_t, tokens = 0.0, {}, 0
    i, n = 0, len(mels)
    while i < n:
        t = max(t, float(arrivals[i]))                # wait for work
        j = i + 1                                     # take what has arrived
        while j - i < n_slots and j < n and arrivals[j] <= t:
            j += 1
        members = list(range(i, j))
        batch = [mels[k] for k in members]
        while len(batch) < n_slots:                   # fixed-shape pad
            batch.append(batch[-1])
        mel = np.concatenate(batch, axis=0)
        budget = max(max_news[k] for k in members)
        res = engine.transcribe(mel, max_new=budget)  # real execution
        t += cal["t_prefill_b"] + res[0].steps * cal["t_step_b"]
        for k in members:
            done_t[k] = t
            tokens += min(max_news[k], res[0].steps)  # row's useful tokens
        i = j
    lat = [done_t[k] - float(arrivals[k]) for k in range(n)]
    return {"tok_s": tokens / max(t, 1e-9), **_latency_summary(lat),
            "makespan_s": t,
            "tokens": tokens, "pdp_j": energy.pdp(t, energy.TPU_V5E_W)}


def _run_continuous(engine: ServeEngine, mels: List[np.ndarray],
                    max_news: List[int], arrivals: np.ndarray,
                    n_slots: int, n_frames: int,
                    cal: Dict[str, float]) -> Dict[str, float]:
    """Continuous batching on the same trace: arrivals are released to the
    scheduler at their Poisson timestamps; admissions and steps advance
    the clock by their calibrated costs; requests complete at their own
    eviction step."""
    sched = ContinuousBatchingScheduler(engine, n_slots=n_slots,
                                        n_frames=n_frames)
    t, done_t = 0.0, {}
    rid2idx: Dict[int, int] = {}
    pending = list(range(len(mels)))
    while pending or sched.n_queued or sched.n_active:
        while pending and arrivals[pending[0]] <= t:
            idx = pending.pop(0)
            rid2idx[sched.submit(mels[idx], max_new=max_news[idx])] = idx
        if sched.n_queued and sched.pool.n_free:
            t += len(sched.admit()) * cal["t_admit"]  # real execution
        if sched.n_active:
            events = sched.decode_step()              # real execution
            t += cal["t_cstep"]
            for ev in events:
                if ev.done:
                    done_t[rid2idx[ev.rid]] = t
        elif pending:
            t = max(t, float(arrivals[pending[0]]))   # idle: jump to arrival
    n = len(mels)
    lat = [done_t[k] - float(arrivals[k]) for k in range(n)]
    tokens = sum(r.steps for r in sched.finished.values())
    att = sched.attribution()
    per_req_sum = sum(att["per_request_pdp_j"].values())
    assert abs(per_req_sum - att["batch_pdp_j"]) <= \
        1e-6 * max(1.0, att["batch_pdp_j"]), \
        "per-request PDP attribution must sum to the batch total (§11.3)"
    return {"tok_s": tokens / max(t, 1e-9), **_latency_summary(lat),
            "makespan_s": t,
            "tokens": tokens, "pdp_j": energy.pdp(t, energy.TPU_V5E_W),
            "attributed_pdp_j": per_req_sum,
            # KV memory accounting (DESIGN.md §15.4): bytes the pool
            # commits up front, and peak fraction holding live data
            "kv_committed_bytes": sched.kv_committed_bytes,
            "kv_utilization": sched.kv_utilization_peak}


def _variant(name: str, cfg, params, quant: str, offload, smoke: bool,
             rng: np.random.Generator) -> Dict[str, object]:
    n_slots = 4
    n_req, n_frames = (12, 16) if smoke else (16, 64)
    # wide max_new spread: the decode budgets' variance is where static
    # batching wastes steps (drained rows idle until the batch max)
    lo, hi = (4, 32) if smoke else (6, 48)
    engine = ServeEngine(cfg, params, max_len=hi + 8, quant=quant,
                         offload=offload, eos_id=-1)
    mels = [rng.standard_normal((1, n_frames, cfg.n_mels)).astype(np.float32)
            for _ in range(n_req)]
    max_news = [int(rng.integers(lo, hi + 1)) for _ in range(n_req)]

    cal = _calibrate(engine, mels[0], n_slots, n_frames)
    traces0 = engine._step_traces

    # Poisson arrivals at ~3x load: mean service per request is
    # mean(max_new) steps of a batch that serves n_slots at once
    mean_gap = cal["t_step_b"] * float(np.mean(max_news)) / (3 * n_slots)
    arrivals = np.cumsum(rng.exponential(mean_gap, n_req))

    st = _run_static(engine, mels, max_news, arrivals, n_slots, cal)
    co = _run_continuous(engine, mels, max_news, arrivals, n_slots,
                         n_frames, cal)
    retraces = engine._step_traces - traces0
    return {"name": name, "static": st, "continuous": co, "cal": cal,
            "retraces_after_warmup": retraces,
            "speedup_tok_s": co["tok_s"] / max(st["tok_s"], 1e-9),
            "p95_ratio": st["p95_s"] / max(co["p95_s"], 1e-9),
            "n_req": n_req, "n_slots": n_slots, "n_frames": n_frames,
            "mean_gap_s": float(mean_gap)}


def _telemetry_drain(cfg, params, smoke: bool) -> obs.Telemetry:
    """Dedicated q8_0+offload scheduler drain carrying telemetry, for the
    §16.2 invariant checks. Deliberately NOT the gated engines: the
    vs-static gate replays calibrated per-step costs, and span recording
    is real host work per step — its budget is gated separately by
    ``benchmarks.telemetry_overhead``."""
    rng = np.random.default_rng(7)
    tele = obs.Telemetry()
    engine = ServeEngine(cfg, params, max_len=24, quant="q8_0",
                         offload=OffloadEngine(interpret=True,
                                               prefer_pallas=False),
                         eos_id=-1, telemetry=tele)
    sched = ContinuousBatchingScheduler(engine, n_slots=2, n_frames=16)
    for _ in range(4 if smoke else 6):
        mel = rng.standard_normal((1, 16, cfg.n_mels)).astype(np.float32)
        sched.submit(mel, max_new=int(rng.integers(3, 8)))
    sched.run()
    return tele


def run(smoke: bool = False, trace_out: str = None,
        metrics_out: str = None) -> dict:
    cfg = get_smoke_config("whisper-tiny") if smoke \
        else get_config("whisper-tiny")
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg, 448)
    variants = []
    for name, quant, off in [
            ("dense", "none", None),
            ("q8_0+offload", "q8_0",
             OffloadEngine(interpret=True, prefer_pallas=False))]:
        rng = np.random.default_rng(0)          # same trace both variants
        variants.append(_variant(name, cfg, params, quant, off, smoke, rng))
    tele = _telemetry_drain(cfg, params, smoke)

    rows = []
    for v in variants:
        for mode in ("static", "continuous"):
            r = v[mode]
            rows.append([v["name"], mode, f"{r['tok_s']:.1f}",
                         f"{r['p50_s']*1e3:.1f}", f"{r['p95_s']*1e3:.1f}",
                         f"{r['p99_s']*1e3:.1f}",
                         f"{r['pdp_j']:.1f}",
                         (f"{r['kv_committed_bytes']/1024:.0f}"
                          if "kv_committed_bytes" in r else "-"),
                         (f"{r['kv_utilization']:.2f}"
                          if "kv_utilization" in r else "-")])
    print("whisper-tiny serving under staggered Poisson arrivals "
          f"({'smoke' if smoke else 'full'} config)")
    print(fmt_table(rows, ["variant", "mode", "tok/s", "p50(ms)", "p95(ms)",
                           "p99(ms)", "PDP(J)", "KV committed(KiB)",
                           "KV util"]))
    ok = True
    for v in variants:
        win = (v["speedup_tok_s"] >= 1.0
               and v["continuous"]["p95_s"] <= v["static"]["p95_s"])
        zero_retrace = v["retraces_after_warmup"] == 0
        ok = ok and win and zero_retrace
        print(f"{v['name']}: continuous {v['speedup_tok_s']:.2f}x tok/s, "
              f"p95 {v['p95_ratio']:.2f}x lower, "
              f"{v['retraces_after_warmup']} retraces after warmup "
              f"-> {'ok' if win and zero_retrace else 'FAIL'}")
    cons = tele.ledger_consistent()
    tele_checks = {"ledger_exact": bool(cons["exact"]),
                   "spans_closed": tele.tracer.all_closed(),
                   "nesting_ok": not tele.tracer.check_nesting()}
    ok = ok and all(tele_checks.values())
    print("telemetry: " + " ".join(f"{k}={'ok' if val else 'FAIL'}"
                                   for k, val in tele_checks.items())
          + f" (claimed {cons['claimed_flops']} == "
            f"ledger {cons['ledger_flops']} FLOPs)")
    if trace_out:
        print("trace written:", tele.write_trace(trace_out))
    if metrics_out:
        print("metrics written:", tele.write_metrics(metrics_out))
    out = {"smoke": smoke, "variants": variants, "gate_ok": ok,
           "telemetry_checks": tele_checks, "ledger_consistency": cons}
    save("continuous_batching", out)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload for the CI gate")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the q8_0+offload variant's Perfetto trace")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write its Prometheus metrics exposition")
    args = ap.parse_args(argv)
    out = run(smoke=args.smoke, trace_out=args.trace_out,
              metrics_out=args.metrics_out)
    return 0 if out["gate_ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
