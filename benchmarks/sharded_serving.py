"""Mesh-sharded vs single-device continuous-batching serve (DESIGN.md §13;
the system-level scale-out the paper's companion CGLA evaluation — and the
ROADMAP's heavy-traffic north star — asks of the §5.1 E2E serving path).

The whole decode step runs as ONE sharded jitted program on a ≥2-device
mesh: the slot pool's slot axis shards over the mesh's "data" axis, the
Whisper weights replicate (data-only mesh — TP would reorder per-row
reductions and break bit-exactness), and admission splices into
device-local slot ranges. The gates, asserted every run (CI via
``--smoke`` on a forced 4-device host mesh,
``XLA_FLAGS=--xla_force_host_platform_device_count=4``):

  - token-exact parity: the sharded scheduler reproduces the
    single-device scheduler's per-request token streams for the same
    arrival trace, for dense bf16 AND q8_0+offload
  - zero step retraces: the sharded fixed-shape slot pool keeps the
    engine's ``step_fn`` at one trace across the whole schedule
  - exact per-device attribution: ``energy_report``'s
    ``dispatch.by_device`` sums to the ledger's total flop count
    (offloaded + fallback + residual), and every mesh device appears
  - plan-cache separation: sharded and unsharded engines at the same
    shapes hold disjoint plan keys (the mesh signature, DESIGN.md §13)

When launched with fewer than 2 visible devices the benchmark re-execs
itself in a subprocess with the forced-host flag (jax pins the device
count at first init — same pattern as launch/dryrun.py).

Per-request latency percentiles (p50/p95/p99) come from the shared
``obs.metrics`` histogram in exact (track_values) mode — the one
percentile implementation across serving benchmarks (DESIGN.md §16.3).

Usage:
  PYTHONPATH=src python -m benchmarks.sharded_serving [--smoke]

Writes experiments/bench/sharded_serving.json.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from typing import Dict, List

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_FORCE_FLAG = "--xla_force_host_platform_device_count=4"


def _reexec_forced(smoke: bool) -> dict:
    """Run this module in a subprocess with 4 forced host devices and load
    its JSON output (the current process's jax already pinned 1 device).
    The child pins ``JAX_PLATFORMS=cpu`` (the force flag only multiplies
    the *host* platform) and sets a sentinel so a child that still cannot
    see 2 devices fails instead of re-exec'ing forever."""
    if os.environ.get("_REPRO_SHARDED_REEXEC"):
        return {"smoke": smoke, "gate_ok": False,
                "error": "re-exec'd child still sees <2 devices"}
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        env["XLA_FLAGS"] = (flags + " " + _FORCE_FLAG).strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["_REPRO_SHARDED_REEXEC"] = "1"
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    cmd = [sys.executable, "-m", "benchmarks.sharded_serving"]
    if smoke:
        cmd.append("--smoke")
    cp = subprocess.run(cmd, cwd=ROOT, env=env, capture_output=True,
                        text=True)
    sys.stdout.write(cp.stdout)
    sys.stderr.write(cp.stderr)
    out_path = os.path.join(ROOT, "experiments", "bench",
                            "sharded_serving.json")
    if cp.returncode == 0 and os.path.exists(out_path):
        with open(out_path) as f:
            return json.load(f)
    return {"smoke": smoke, "gate_ok": False,
            "error": f"forced-host subprocess exited {cp.returncode}"}


def _latency_summary(xs: List[float]) -> Dict[str, float]:
    """p50/p95/p99 through the ONE shared percentile implementation
    (repro.obs.metrics, DESIGN.md §16.3) in exact mode, matching the
    other serving benchmarks."""
    from repro.obs.metrics import LATENCY_BUCKETS_S, Histogram

    h = Histogram("latency_s", LATENCY_BUCKETS_S, track_values=True)
    for x in xs:
        h.observe(x)
    return {"p50_s": h.percentile(50), "p95_s": h.percentile(95),
            "p99_s": h.percentile(99)}


def _serve_trace(engine, mels: List, max_news: List[int], n_slots: int,
                 n_frames: int) -> Dict[str, object]:
    """Drive one engine's scheduler over the arrival trace; return token
    streams (keyed by submit order) and wall-clock busy time."""
    sched = engine.scheduler(n_slots=n_slots, n_frames=n_frames)
    rids = [sched.submit(m, max_new=mn) for m, mn in zip(mels, max_news)]
    t0 = time.perf_counter()
    got = sched.run()
    wall = time.perf_counter() - t0
    tokens = [got[r].tokens for r in rids]
    steps = sum(got[r].steps for r in rids)
    return {"tokens": tokens, "wall_s": wall, "steps": steps,
            "tok_s": steps / max(wall, 1e-9),
            "step_traces": sched.step_traces,
            **_latency_summary([got[r].total_s for r in rids]),
            # KV memory accounting (DESIGN.md §15.4)
            "kv_committed_bytes": sched.kv_committed_bytes,
            "kv_utilization": sched.kv_utilization_peak}


def _variant(name: str, cfg, params, quant: str, make_offload, mesh,
             smoke: bool) -> Dict[str, object]:
    import numpy as np

    from repro.serve.engine import ServeEngine

    n_slots = 4
    n_req, n_frames = (8, 16) if smoke else (16, 32)
    lo, hi = (3, 12) if smoke else (6, 24)
    rng = np.random.default_rng(0)
    mels = [rng.standard_normal((1, n_frames, cfg.n_mels)).astype(np.float32)
            for _ in range(n_req)]
    max_news = [int(rng.integers(lo, hi + 1)) for _ in range(n_req)]

    eng1 = ServeEngine(cfg, params, max_len=hi + 8, quant=quant,
                       offload=make_offload(), eos_id=-1)
    engm = ServeEngine(cfg, params, max_len=hi + 8, quant=quant,
                       offload=make_offload(), eos_id=-1, mesh=mesh)
    r1 = _serve_trace(eng1, mels, max_news, n_slots, n_frames)
    rm = _serve_trace(engm, mels, max_news, n_slots, n_frames)

    parity = r1["tokens"] == rm["tokens"]
    # one trace per engine total: the slot pool never changes shape, so
    # the whole schedule compiles the step exactly once (zero retraces)
    zero_retrace = r1["step_traces"] == 1 and rm["step_traces"] == 1

    checks = {"parity": parity, "zero_retrace": zero_retrace}
    report = {}
    if eng1.offload is not None:
        st = engm.offload.stats
        total = st.offloaded_flops + st.fallback_flops + st.residual_flops
        by_dev = engm.energy_report([])["dispatch"]["by_device"]
        n_mesh_dev = 1
        for a in mesh.axis_names:
            n_mesh_dev *= mesh.shape[a]
        checks["by_device_sums"] = sum(by_dev.values()) == total
        checks["all_devices_attributed"] = len(by_dev) == n_mesh_dev
        keys1 = set(eng1._plans.plans)
        keysm = set(engm._plans.plans)
        checks["plan_keys_disjoint"] = not (keys1 & keysm)
        report["by_device"] = by_dev
        report["ledger_flops"] = total
    ok = all(checks.values())
    return {"name": name, "single": {k: v for k, v in r1.items()
                                     if k != "tokens"},
            "sharded": {k: v for k, v in rm.items() if k != "tokens"},
            "checks": checks, "ok": ok, "n_req": n_req, "n_slots": n_slots,
            "n_frames": n_frames, **report}


def run(smoke: bool = False) -> dict:
    import jax

    if len(jax.devices()) < 2:
        return _reexec_forced(smoke)

    import jax.random  # noqa: F401

    from benchmarks.common import fmt_table, save
    from repro.configs.registry import get_config, get_smoke_config
    from repro.core.offload import OffloadEngine
    from repro.launch.mesh import make_serve_mesh
    from repro.models import model as model_lib

    cfg = get_smoke_config("whisper-tiny") if smoke \
        else get_config("whisper-tiny")
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg, 448)
    mesh = make_serve_mesh()          # data-only: bit-exact parity

    variants = [
        _variant("dense", cfg, params, "none", lambda: None, mesh, smoke),
        _variant("q8_0+offload", cfg, params, "q8_0",
                 lambda: OffloadEngine(interpret=True, prefer_pallas=False),
                 mesh, smoke),
    ]

    rows = []
    for v in variants:
        for mode in ("single", "sharded"):
            r = v[mode]
            rows.append([v["name"], mode, f"{r['tok_s']:.1f}",
                         f"{r['p50_s']*1e3:.0f}", f"{r['p95_s']*1e3:.0f}",
                         f"{r['p99_s']*1e3:.0f}",
                         str(r["steps"]), str(r["step_traces"]),
                         f"{r['kv_committed_bytes']/1024:.0f}",
                         f"{r['kv_utilization']:.2f}"])
    n_dev = len(jax.devices())
    print(f"whisper-tiny sharded serving on a {n_dev}-device host mesh "
          f"({'smoke' if smoke else 'full'} config)")
    print(fmt_table(rows, ["variant", "mode", "tok/s", "p50(ms)", "p95(ms)",
                           "p99(ms)", "steps", "traces",
                           "KV committed(KiB)", "KV util"]))
    ok = True
    for v in variants:
        ok = ok and v["ok"]
        detail = " ".join(f"{k}={'ok' if val else 'FAIL'}"
                          for k, val in v["checks"].items())
        print(f"{v['name']}: {detail} -> {'ok' if v['ok'] else 'FAIL'}")
    out = {"smoke": smoke, "n_devices": n_dev,
           "mesh": [[a, int(mesh.shape[a])] for a in mesh.axis_names],
           "variants": variants, "gate_ok": ok}
    save("sharded_serving", out)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload for the CI gate")
    args = ap.parse_args(argv)
    out = run(smoke=args.smoke)
    return 0 if out["gate_ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
