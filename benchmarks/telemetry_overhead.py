"""Telemetry overhead + consistency gate (DESIGN.md §16.4; guards the
§5.1 serving-path measurements every other benchmark reports).

The observability subsystem promises to be ignorable: spans, instants,
and metric updates are host-side bookkeeping between jitted steps, never
inside them, so switching telemetry on must not move the serving numbers.
This benchmark prices that promise and gates it: two identical
q8_0+offload whisper-tiny engines — one with a live ``obs.Telemetry``,
one with ``telemetry=None`` — drain the SAME continuous-batching request
trace in lockstep, every decode step timed individually.

Gates, asserted every run (exit code gates CI via ``--smoke``):

  - overhead: telemetry-on per-decode-step cost <= 1.03x telemetry-off
    (the ≤3% budget from DESIGN.md §16.4). The two schedulers advance in
    LOCKSTEP — identical traces, alternating single steps — and the
    overhead estimate is the MEDIAN of the paired per-step deltas over
    the median off-step cost. Pairing cancels run-scale drift (frequency
    scaling, cache pressure land on both modes alike); the median
    rejects the spikes (GC, noisy neighbors) that make min- or
    mean-based estimates flap on a shared machine while keeping the
    deterministic telemetry cost every step pays
  - zero retraces with telemetry ON: instrumenting must not perturb the
    jitted step (one step trace across the whole drain)
  - ledger consistency EXACT: the sum of ledger-span FLOP/call deltas
    equals the engine ledger's totals as integers (§16.2) — no double
    count, no leak
  - lifecycle closure: every submitted rid's phase spans close, and
    per-track span nesting holds
  - histogram soundness: for every registry histogram,
    ``sum(bucket_counts) == count`` (the +Inf bucket catches the tail)
  - trace validity: the emitted Perfetto JSON passes
    ``tools/check_trace.py`` structural validation

Usage:
  PYTHONPATH=src python -m benchmarks.telemetry_overhead [--smoke]
      [--trace-out PATH] [--metrics-out PATH]

Writes experiments/bench/telemetry_overhead.json (and the trace/metrics
artifacts next to it by default).
"""
from __future__ import annotations

import argparse
import importlib.util
import os
import statistics
import time
from typing import Dict, List

import jax
import numpy as np

from benchmarks.common import OUT_DIR, ROOT, fmt_table, save
from repro import obs
from repro.configs.registry import get_config, get_smoke_config
from repro.core.offload import OffloadEngine
from repro.models import model as model_lib
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import ContinuousBatchingScheduler

OVERHEAD_BUDGET = 0.03


def _load_check_trace():
    """Import tools/check_trace.py by path (tools/ is not a package)."""
    path = os.path.join(ROOT, "tools", "check_trace.py")
    spec = importlib.util.spec_from_file_location("check_trace", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _drain(engine: ServeEngine, mels: List[np.ndarray],
           max_news: List[int], n_slots: int, n_frames: int) -> float:
    """One full scheduler drain (used for warmup); returns wall seconds."""
    sched = ContinuousBatchingScheduler(engine, n_slots=n_slots,
                                        n_frames=n_frames)
    for m, mn in zip(mels, max_news):
        sched.submit(m, max_new=mn)
    t0 = time.perf_counter()
    sched.run()
    return time.perf_counter() - t0


def _paired_drain(engines: Dict[str, ServeEngine], mels: List[np.ndarray],
                  max_news: List[int], n_slots: int, n_frames: int,
                  step_ts: Dict[str, List[float]]) -> None:
    """Drain the SAME trace through both modes' schedulers in LOCKSTEP,
    timing every ``decode_step`` call individually. The two schedules are
    identical (same arrivals, same budgets, fixed-shape batch step), so
    each adjacent off/on step pair sees the same machine state — run-
    scale drift (frequency scaling, cache pressure) lands on both modes
    alike instead of splitting them the way coarser interleaving lets
    it."""
    scheds = {mode: ContinuousBatchingScheduler(eng, n_slots=n_slots,
                                                n_frames=n_frames)
              for mode, eng in engines.items()}
    for s in scheds.values():
        for m, mn in zip(mels, max_news):
            s.submit(m, max_new=mn)
    while any(s.n_queued or s.n_active for s in scheds.values()):
        for mode, s in scheds.items():
            if s.n_queued:
                s.admit()
            if s.n_active:
                t0 = time.perf_counter()
                s.decode_step()
                step_ts[mode].append(time.perf_counter() - t0)
    for s in scheds.values():
        # manual decode_step driving buffers metric observations; drain
        # them into the registry outside the timed region (§16.4)
        s.flush_telemetry()


def run(smoke: bool = False, trace_out: str = None,
        metrics_out: str = None) -> dict:
    cfg = get_smoke_config("whisper-tiny") if smoke \
        else get_config("whisper-tiny")
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg, 448)

    n_slots = 4
    n_req, n_frames = (8, 16) if smoke else (16, 32)
    lo, hi = (3, 10) if smoke else (6, 24)
    rounds = 5
    rng = np.random.default_rng(0)
    mels = [rng.standard_normal((1, n_frames, cfg.n_mels)).astype(np.float32)
            for _ in range(n_req)]
    max_news = [int(rng.integers(lo, hi + 1)) for _ in range(n_req)]

    tele = obs.Telemetry()
    engines = {
        "off": ServeEngine(cfg, params, max_len=hi + 8, quant="q8_0",
                           offload=OffloadEngine(interpret=True,
                                                 prefer_pallas=False),
                           eos_id=-1),
        "on": ServeEngine(cfg, params, max_len=hi + 8, quant="q8_0",
                          offload=OffloadEngine(interpret=True,
                                                prefer_pallas=False),
                          eos_id=-1, telemetry=tele),
    }

    # warmup: compile the admission prefill + shared decode step on both
    # engines, then freeze the retrace counter — the zero-retrace gate
    # below covers the measured rounds only
    for eng in engines.values():
        _drain(eng, mels[:2], max_news[:2], n_slots, n_frames)
    traces0 = {k: eng._step_traces for k, eng in engines.items()}

    # lockstep rounds -> paired per-step deltas. Pairing cancels drift,
    # the median rejects spikes; the deterministic telemetry cost every
    # step pays is exactly what survives both.
    step_ts: Dict[str, List[float]] = {"off": [], "on": []}
    for _ in range(rounds):
        _paired_drain(engines, mels, max_news, n_slots, n_frames, step_ts)
    n_pairs = min(len(step_ts["off"]), len(step_ts["on"]))
    deltas = [step_ts["on"][i] - step_ts["off"][i] for i in range(n_pairs)]
    med = {mode: statistics.median(ts) for mode, ts in step_ts.items()}
    overhead = statistics.median(deltas) / max(med["off"], 1e-9)
    retraces = {k: engines[k]._step_traces - traces0[k]
                for k in engines}

    # §16.2 consistency over everything the telemetry engine ran
    # (warmup + all rounds): spans and ledger cover the same window
    # because bind_ledger happens at engine construction
    cons = tele.ledger_consistent()
    tele.sync_ledger_metrics()
    hist_ok = all(
        sum(c for _, c in h["buckets"]) == h["count"]
        for h in tele.metrics.snapshot()["histograms"].values())

    trace_out = trace_out or os.path.join(OUT_DIR,
                                          "telemetry_overhead.trace.json")
    metrics_out = metrics_out or os.path.join(
        OUT_DIR, "telemetry_overhead.metrics.prom")
    os.makedirs(OUT_DIR, exist_ok=True)
    tele.write_trace(trace_out)
    tele.write_metrics(metrics_out)
    import json as _json
    with open(trace_out) as f:
        trace_errors = _load_check_trace().validate(_json.load(f))

    checks = {
        "overhead_within_budget": overhead <= OVERHEAD_BUDGET,
        "zero_retrace_on": retraces["on"] == 0,
        "zero_retrace_off": retraces["off"] == 0,
        "ledger_exact": bool(cons["exact"]),
        "spans_closed": tele.tracer.all_closed(),
        "nesting_ok": not tele.tracer.check_nesting(),
        "histogram_sums": hist_ok,
        "trace_valid": not trace_errors,
    }
    ok = all(checks.values())

    rows = [[mode, f"{med[mode]*1e6:.1f}",
             f"{len(step_ts[mode])}",
             f"{n_slots / max(med[mode], 1e-9):.0f}",
             str(retraces[mode])] for mode in ("off", "on")]
    print(f"whisper-tiny telemetry overhead, {n_req} requests x {rounds} "
          f"lockstep rounds ({'smoke' if smoke else 'full'} config)")
    print(fmt_table(rows, ["telemetry", "med step(us)", "steps",
                           "tok/s@med", "retraces"]))
    print(f"overhead: {overhead*100:+.2f}% (budget {OVERHEAD_BUDGET:.0%}) | "
          + " ".join(f"{k}={'ok' if v else 'FAIL'}"
                     for k, v in checks.items())
          + f" -> {'ok' if ok else 'FAIL'}")
    print(f"ledger: claimed {cons['claimed_flops']} == "
          f"{cons['ledger_flops']} FLOPs, {cons['claimed_calls']} == "
          f"{cons['ledger_calls']} calls")
    for e in trace_errors:
        print(f"  trace: {e}")

    out = {"smoke": smoke, "rounds": rounds, "n_req": n_req,
           "median_step_s": med,
           "n_steps": {k: len(v) for k, v in step_ts.items()},
           "overhead": overhead,
           "budget": OVERHEAD_BUDGET, "retraces": retraces,
           "ledger_consistency": cons, "checks": checks, "gate_ok": ok,
           "trace_path": trace_out, "metrics_path": metrics_out}
    save("telemetry_overhead", out)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload for the CI gate")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="Perfetto trace destination (default: "
                         "experiments/bench/telemetry_overhead.trace.json)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="Prometheus exposition destination (default: "
                         "experiments/bench/telemetry_overhead.metrics.prom)")
    args = ap.parse_args(argv)
    out = run(smoke=args.smoke, trace_out=args.trace_out,
              metrics_out=args.metrics_out)
    return 0 if out["gate_ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
